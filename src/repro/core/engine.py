"""Inference engine: loads a model bundle once, jit-compiles its apply, and
serves region invocations (the Torch-C++ role in the paper's runtime).

Supports sharded inference: with a mesh installed (``repro.dist.sharding
.use_mesh``), surrogate batches are placed and constrained over the
``data`` axis, so ``MLRegion`` inference scales across chips like any
other data-parallel workload — the compiled apply is cached per sharding
context, so the same engine serves eager CPU calls and sharded meshes.
On TPU the engine routes pure-MLP bundles through the ``fused_mlp``
Pallas kernel (all layers resident in VMEM — the paper's Observation 2,
hardware-utilization, reinterpreted for TPU).

Bundles retrained in-process (the NAS loop rewrites ``params.npz``) are
not served stale: ``get()`` re-reads a bundle whose on-disk fingerprint
(mtime_ns + size) changed since load, and ``invalidate()``/``reload()``
force it — retrain paths that bypass the fingerprint (exotic filesystems
with coarse timestamps) should call ``invalidate()`` after writing.
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain, current_ctx
from repro.nn.serialize import load_model


def _bundle_mtime(path: str) -> tuple:
    """(mtime_ns, size) fingerprint of the bundle files.

    ns resolution closes the same-second rewrite window on modern
    filesystems; in-process retrain paths (nas.nested.save_trial) call
    invalidate() explicitly and do not rely on this.
    """
    newest, total = 0, 0
    for name in ("spec.json", "params.npz"):
        f = os.path.join(path, name)
        if os.path.exists(f):
            stat = os.stat(f)
            newest = max(newest, stat.st_mtime_ns)
            total += stat.st_size
    return (newest, total)


class InferenceEngine:
    _cache: dict = {}

    def __init__(self, model_path: str, use_kernel: str = "auto"):
        self.path = str(model_path)
        self.use_kernel = use_kernel
        self._applies: dict = {}  # one compiled apply per sharding context
        # resolved NamedSharding per (shape, mesh, multi_pod): spec_for is
        # pure python over every dim and was re-run on every eager call
        self._shardings: dict = {}
        self._load()

    def _load(self):
        # a region's first call can happen inside someone else's jit trace
        # (predicated lax.cond, infer_async degrading in-trace): params
        # must be concrete arrays, never constants staged onto that trace
        with jax.ensure_compile_time_eval():
            self.net, self.params, self.spec = load_model(self.path)
        self._mtime = _bundle_mtime(self.path)
        self._applies.clear()
        self._shardings.clear()

    @classmethod
    def get(cls, model_path) -> "InferenceEngine":
        """Process-wide cache: a model file is loaded once (paper §IV-B).

        A bundle rewritten on disk since it was loaded (NAS retraining)
        is transparently reloaded in place, so long-lived regions holding
        this engine see the fresh weights.
        """
        key = str(model_path)
        eng = cls._cache.get(key)
        if eng is None:
            eng = cls._cache[key] = cls(key)
        elif _bundle_mtime(key) != eng._mtime:
            # any fingerprint change reloads — including rollbacks to an
            # older bundle (copy2/mv preserve the original, older mtime)
            eng.reload()
        return eng

    @classmethod
    def invalidate(cls, model_path=None):
        """Drop cached engine(s) so the next get() reloads from disk."""
        if model_path is None:
            cls._cache.clear()
        else:
            cls._cache.pop(str(model_path), None)

    def reload(self):
        """Re-read the bundle from disk and drop compiled applies."""
        self._load()

    def _is_pure_mlp(self):
        kinds = [l["kind"] for l in self.spec["layers"]]
        return all(k in ("dense", "act", "flatten") for k in kinds)

    def _build(self, ctx=None):
        net = self.net
        extra = self.spec.get("extra") or {}
        norm = None
        if "x_mu" in extra:
            import numpy as np
            ish = tuple(self.spec["in_shape"][1:])
            osh = tuple(net.out_shape()[1:])
            norm = tuple(jnp.asarray(np.asarray(extra[k], np.float32)
                                     .reshape(s))
                         for k, s in (("x_mu", ish), ("x_sd", ish),
                                      ("y_mu", osh), ("y_sd", osh)))

        if self.use_kernel != "never" and self._is_pure_mlp() and \
                jax.default_backend() == "tpu":
            from repro.kernels.fused_mlp import ops as fused_ops
            # under a multi-shard data axis the kernel runs per shard via
            # shard_map, keeping the VMEM-resident fast path under GSPMD
            mesh = ctx.mesh if ctx is not None else None
            data_axes = (ctx.mesh_axes_for("data") if ctx is not None
                         else ())

            def raw(params, x):
                return fused_ops.fused_mlp_from_spec(
                    self.spec, params, x, mesh=mesh, data_axes=data_axes)
        else:
            def raw(params, x):
                return net.apply(params, x)

        def apply_fn(params, x):
            x = constrain(x, *(("data",) + (None,) * (x.ndim - 1)))
            if norm is not None:
                x = (x - norm[0]) / norm[1]
            y = raw(params, x)
            if norm is not None:
                y = y * norm[3] + norm[2]
            return constrain(y, *(("data",) + (None,) * (y.ndim - 1)))

        return jax.jit(apply_fn)

    def _apply_for(self, ctx):
        """Compiled apply for the active sharding context (traced under it,
        so the data-axis constraints bind to that mesh)."""
        key = (ctx.mesh, ctx.multi_pod) if ctx is not None else None
        fn = self._applies.get(key)
        if fn is None:
            fn = self._applies[key] = self._build(ctx)
        return fn

    def _place(self, x, ctx):
        """Batch placement over the data axis, with the resolved sharding
        cached per (shape, mesh): spec resolution ran on *every* eager
        call before, and device_put is skipped when x already lives there
        (repeated bucket shapes from the serve batcher)."""
        if ctx is None or ctx.mesh is None or isinstance(x, jax.core.Tracer):
            return x
        key = (x.shape, ctx.mesh, ctx.multi_pod)
        if key not in self._shardings:
            self._shardings[key] = ctx.sharding_for(
                x.shape, ("data",) + (None,) * (x.ndim - 1))
        sharding = self._shardings[key]
        if sharding is not None and getattr(x, "sharding", None) != sharding:
            x = jax.device_put(x, sharding)
        return x

    def __call__(self, x):
        ctx = current_ctx()
        fn = self._apply_for(ctx)
        # place the surrogate batch over the data axis before compute
        # so per-chip work is batch/n_data_shards
        return fn(self.params, self._place(x, ctx))

    def apply_batched(self, x, *, min_bucket: int = 8):
        """Serve a coalesced mega-batch: rows padded up to the next
        power-of-two bucket so the jit cache stays at <= log2(max batch)
        entries per context, then sliced back to the caller's row count.
        Under a mesh the bucket floor is raised to the data-shard count
        (and rounded to a multiple of it), so small batches never lose
        the data axis to the divisibility fallback.

        Row-wise nets make the padding invisible: output row i depends
        only on input row i, so callers get bit-identical rows to a
        same-input synchronous ``__call__`` (tests/test_serve.py).
        """
        from repro.serve.batcher import bucket_for
        ctx = current_ctx()
        shards = (ctx.axis_size("data")
                  if ctx is not None and ctx.mesh is not None else 1)
        n = int(x.shape[0])
        b = bucket_for(n, min_bucket, shards)
        if b != n:
            x = jnp.concatenate(
                [x, jnp.zeros((b - n,) + x.shape[1:], x.dtype)], axis=0)
        return self(x)[:n]

    def infer_shape(self, in_shape):
        return self.net.out_shape()
