"""Inference engine: loads a model bundle once, jit-compiles its apply, and
serves region invocations (the Torch-C++ role in the paper's runtime).

Supports sharded inference: with a mesh installed (``repro.dist.sharding
.use_mesh``), surrogate batches are placed and constrained over the
``data`` axis, so ``MLRegion`` inference scales across chips like any
other data-parallel workload — the compiled apply is cached per sharding
context, so the same engine serves eager CPU calls and sharded meshes.
On TPU the engine routes pure-MLP bundles through the ``fused_mlp``
Pallas kernel (all layers resident in VMEM — the paper's Observation 2,
hardware-utilization, reinterpreted for TPU).

Bundles retrained in-process (the NAS loop rewrites ``params.npz``) are
not served stale: ``get()`` re-reads a bundle whose on-disk fingerprint
(mtime_ns + size) changed since load, and ``invalidate()``/``reload()``
force it — retrain paths that bypass the fingerprint (exotic filesystems
with coarse timestamps) should call ``invalidate()`` after writing.
"""
from __future__ import annotations

import os
import threading
import warnings
from typing import Optional

import jax
import jax.numpy as jnp

_donation_warning_muted = False


def _mute_donation_warning_off_tpu():
    """On backends without donation support (cpu) "Some donated buffers
    were not usable" fires for every donated apply and means nothing —
    donation there is a declared intent, not a memory saving.  On TPU
    the warning is a real signal (an expected aliasing didn't happen),
    so it is left alone.  Registered lazily at first donated build: the
    backend query must not run at import time (it would initialize jax
    before callers set XLA_FLAGS)."""
    global _donation_warning_muted
    if _donation_warning_muted or jax.default_backend() == "tpu":
        return
    warnings.filterwarnings("ignore",
                            message="Some donated buffers were not usable")
    _donation_warning_muted = True

from repro.dist.sharding import constrain, current_ctx
from repro.nn.serialize import load_model
from repro.obs import TRACER


def bundle_norm(spec, net):
    """The bundle's (x_mu, x_sd, y_mu, y_sd) normalization arrays, or
    None when it was trained unnormalized.  Shared with the quant gate
    (:mod:`repro.quant.gate`), which must compare f32 and int8-simulated
    outputs in the same physical units the per-bundle RMSE budgets are
    written in."""
    extra = spec.get("extra") or {}
    if "x_mu" not in extra:
        return None
    import numpy as np
    ish = tuple(spec["in_shape"][1:])
    osh = tuple(net.out_shape()[1:])
    return tuple(jnp.asarray(np.asarray(extra[k], np.float32).reshape(s))
                 for k, s in (("x_mu", ish), ("x_sd", ish),
                              ("y_mu", osh), ("y_sd", osh)))


def _bundle_mtime(path: str) -> tuple:
    """(mtime_ns, size) fingerprint of the bundle files.

    ns resolution closes the same-second rewrite window on modern
    filesystems; in-process retrain paths (nas.nested.save_trial) call
    invalidate() explicitly and do not rely on this.
    """
    newest, total = 0, 0
    for name in ("spec.json", "params.npz"):
        f = os.path.join(path, name)
        if os.path.exists(f):
            stat = os.stat(f)
            newest = max(newest, stat.st_mtime_ns)
            total += stat.st_size
    return (newest, total)


class InferenceEngine:
    _cache: dict = {}
    # guards _cache and in-place reloads: concurrent get() calls on an
    # evicted/stale bundle must produce exactly ONE reload (the serve
    # path may race a residency eviction from another thread), and a
    # reader must never observe a half-loaded engine.  Reentrant: a
    # load under the lock may evict LRU victims, which pops this same
    # cache.
    _cache_lock = threading.RLock()

    def __init__(self, model_path: str, use_kernel: str = "auto"):
        self.path = str(model_path)
        self.use_kernel = use_kernel
        self._applies: dict = {}  # one compiled apply per sharding context
        # resolved NamedSharding per (shape, mesh, multi_pod): spec_for is
        # pure python over every dim and was re-run on every eager call
        self._shardings: dict = {}
        # (apply id, batch shape) pairs already executed once: a batched
        # apply whose pair is unseen is paying its jit compile, and the
        # obs span marks it so — compile spikes stop looking like serving
        self._seen_shapes: set = set()
        self._load()

    def _load(self):
        # a region's first call can happen inside someone else's jit trace
        # (predicated lax.cond, infer_async degrading in-trace): params
        # must be concrete arrays, never constants staged onto that trace
        with jax.ensure_compile_time_eval():
            self.net, self.params, self.spec = load_model(self.path)
        self._mtime = _bundle_mtime(self.path)
        self._applies.clear()
        self._shardings.clear()
        self._seen_shapes.clear()
        # precision tier is a load-time property: the gate verdict is
        # bound to the bundle fingerprint, so any reload re-resolves it
        # (gate_bundle() invalidates the engine cache after a verdict)
        self._qlayers = None
        self._qacts = None
        self.tier = self._resolve_tier()
        if self.tier == "int8":
            self._quantize_residency()
        # residency accounting: meter this load's bytes against the LRU
        # byte budget and drop whatever the manager says must go.  The
        # victims leave through invalidate() — eviction and retrain
        # invalidation share one path on purpose.
        self.resident_nbytes = self._params_nbytes()
        from repro.serve.residency import RESIDENCY
        for victim in RESIDENCY.note_load(self.path, self.resident_nbytes):
            type(self).invalidate(victim)

    def _params_nbytes(self) -> int:
        """Bytes of device residency this bundle's weights occupy
        (params, plus the int8 layers + scales when quantized)."""
        import numpy as np

        def nbytes(leaf) -> int:
            try:
                return int(leaf.size) * int(np.dtype(leaf.dtype).itemsize)
            except Exception:
                return 0

        total = sum(nbytes(p) for p in jax.tree_util.tree_leaves(self.params))
        if self._qlayers is not None:
            total += sum(nbytes(a)
                         for a in jax.tree_util.tree_leaves(self._qlayers))
        return total

    def _resolve_tier(self) -> str:
        """Which precision tier this engine serves (resolved once per
        load — the serve path must not re-read env vars or gate files
        per batch).

        ``REPRO_QUANT`` modes: ``auto`` (default) serves int8 only on
        TPU — off-TPU the int8-simulating oracle is *slower* than the
        f32 path, so quantization buys nothing; ``force``/``1`` serves
        int8 on any backend (CI drills the full quantized path in
        interpret/oracle mode); ``never``/``0`` pins f32.  In every mode
        except ``never`` the bundle must have **passed its accuracy
        gate** — a gate-fail (or stale/absent) verdict serves f32 even
        under ``force``; that is the fail-safe the gate exists for.
        """
        mode = os.environ.get("REPRO_QUANT", "auto").strip().lower()
        if mode in ("never", "0", "off"):
            return "f32"
        if self.use_kernel == "never" or not self._is_pure_mlp():
            return "f32"
        if mode not in ("force", "1") and jax.default_backend() != "tpu":
            return "f32"
        try:
            from repro.quant.gate import gate_passed
            if not gate_passed(self.path):
                return "f32"
        except Exception:
            return "f32"
        return "int8"

    def _quantize_residency(self):
        """Quantize the dense stack once at load (per-output-channel
        int8 weights + f32 scales), using the exact ``scale_mult`` the
        gate verdict blessed — serving must run the same numbers the
        gate measured, not a fresh calibration."""
        from repro.kernels.fused_mlp.ops import mlp_stack_from_spec
        from repro.quant.gate import verdict
        from repro.quant.quantize import quantize_params
        rec = verdict(self.path) or {}
        sm = float(rec.get("scale_mult", 1.0))
        with jax.ensure_compile_time_eval():
            _, weights, biases, acts = mlp_stack_from_spec(
                self.spec, self.params, jnp.zeros((1, 1), jnp.float32))
            self._qlayers = tuple(
                tuple(q) for q in quantize_params(weights, biases,
                                                  scale_mult=sm))
        self._qacts = tuple(acts)
        from repro.obs import metrics as _m
        _m.counter("repro_quant_eligible_total",
                   "bundle loads that resolved to the int8 tier",
                   ("bundle",)).inc(1, bundle=self.path)

    @classmethod
    def get(cls, model_path) -> "InferenceEngine":
        """Process-wide cache: a model file is loaded once (paper §IV-B).

        A bundle rewritten on disk since it was loaded (NAS retraining)
        is transparently reloaded in place, so long-lived regions holding
        this engine see the fresh weights.
        """
        key = str(model_path)
        with cls._cache_lock:
            eng = cls._cache.get(key)
            if eng is None:
                eng = cls._cache[key] = cls(key)
            elif _bundle_mtime(key) != eng._mtime:
                # any fingerprint change reloads — including rollbacks to
                # an older bundle (copy2/mv preserve the original, older
                # mtime)
                eng.reload()
        from repro.serve.residency import RESIDENCY
        RESIDENCY.touch(key)
        return eng

    @classmethod
    def invalidate(cls, model_path=None):
        """Drop cached engine(s) so the next get() reloads from disk.

        Residency eviction lands here too: the manager's LRU victims are
        invalidated exactly like a retrained bundle, so both reload
        through the same get() path."""
        with cls._cache_lock:
            if model_path is None:
                cls._cache.clear()
            else:
                cls._cache.pop(str(model_path), None)
        from repro.serve.residency import RESIDENCY
        RESIDENCY.drop(model_path)

    def reload(self):
        """Re-read the bundle from disk and drop compiled applies."""
        self._load()

    def _is_pure_mlp(self):
        kinds = [l["kind"] for l in self.spec["layers"]]
        return all(k in ("dense", "act", "flatten") for k in kinds)

    def _build(self, ctx=None, donate: bool = False):
        net = self.net
        norm = bundle_norm(self.spec, net)
        mesh = ctx.mesh if ctx is not None else None
        data_axes = (ctx.mesh_axes_for("data") if ctx is not None else ())

        if self.tier == "int8" and self._qlayers is not None:
            # gated quantized tier: serve the load-time int8 residency.
            # On TPU this dispatches the fused_mlp_int8 Pallas kernel;
            # off-TPU (REPRO_QUANT=force drills) the registry routes the
            # same call to the int8-simulating jnp oracle, so the served
            # numbers are the gated numbers on every backend.
            from repro.kernels.fused_mlp import int8 as qops
            qlayers = self._qlayers

            def raw(params, x):
                return qops.fused_mlp_int8_from_spec(
                    self.spec, list(qlayers), x, mesh=mesh,
                    data_axes=data_axes)
        elif self.use_kernel != "never" and self._is_pure_mlp() and \
                jax.default_backend() == "tpu":
            from repro.kernels.fused_mlp import ops as fused_ops
            # under a multi-shard data axis the kernel runs per shard via
            # shard_map, keeping the VMEM-resident fast path under GSPMD

            def raw(params, x):
                return fused_ops.fused_mlp_from_spec(
                    self.spec, params, x, mesh=mesh, data_axes=data_axes)
        else:
            def raw(params, x):
                return net.apply(params, x)

        def apply_fn(params, x):
            x = constrain(x, *(("data",) + (None,) * (x.ndim - 1)))
            if norm is not None:
                x = (x - norm[0]) / norm[1]
            y = raw(params, x)
            if norm is not None:
                y = y * norm[3] + norm[2]
            return constrain(y, *(("data",) + (None,) * (y.ndim - 1)))

        if donate:
            _mute_donation_warning_off_tpu()
        return jax.jit(apply_fn, donate_argnums=(1,) if donate else ())

    def _apply_for(self, ctx, donate: bool = False):
        """Compiled apply for the active sharding context (traced under it,
        so the data-axis constraints bind to that mesh).

        ``donate=True`` compiles a variant that donates the batch buffer
        to XLA (the serve path owns its padded mega-batches, so their
        input buffers are dead after dispatch and can back the outputs).
        Kept as a separate cache entry: a donated apply must never serve
        a caller-owned array.
        """
        # a mesh-less ctx (use_mesh(None), e.g. the batcher re-installing
        # a no-mesh submitter's context) compiles to the same program as
        # no ctx at all — share the cache entry or the serve path pays a
        # duplicate compile for every bucket shape
        key = (ctx.mesh, ctx.multi_pod) \
            if ctx is not None and ctx.mesh is not None else None
        if donate:
            key = (key, "donate")
        fn = self._applies.get(key)
        if fn is None:
            fn = self._applies[key] = self._build(ctx, donate=donate)
        return fn

    def _place(self, x, ctx):
        """Batch placement over the data axis, with the resolved sharding
        cached per (shape, mesh): spec resolution ran on *every* eager
        call before, and device_put is skipped when x already lives there
        (repeated bucket shapes from the serve batcher)."""
        if ctx is None or ctx.mesh is None or isinstance(x, jax.core.Tracer):
            return x
        if isinstance(x, jax.Array) and not x.is_fully_addressable:
            # a global (multi-process) array was already placed at
            # assembly (ShardCtx.make_global); a device_put here would be
            # a cross-process reshard and raises on most backends
            return x
        key = (x.shape, ctx.mesh, ctx.multi_pod)
        if key not in self._shardings:
            self._shardings[key] = ctx.sharding_for(
                x.shape, ("data",) + (None,) * (x.ndim - 1))
        sharding = self._shardings[key]
        if sharding is not None and getattr(x, "sharding", None) != sharding:
            x = jax.device_put(x, sharding)
        return x

    def __call__(self, x):
        ctx = current_ctx()
        fn = self._apply_for(ctx)
        # place the surrogate batch over the data axis before compute
        # so per-chip work is batch/n_data_shards
        return fn(self.params, self._place(x, ctx))

    def apply_batched(self, x, *, min_bucket: int = 8,
                      donate: bool = False, prepadded: bool = False):
        """Serve a coalesced mega-batch: rows padded up to the next
        power-of-two bucket so the jit cache stays at <= log2(max batch)
        entries per context, then sliced back to the caller's row count.
        Under a mesh the bucket floor is raised to the data-shard count
        (and rounded to a multiple of it), so small batches never lose
        the data axis to the divisibility fallback.

        ``donate=True`` asserts the caller owns ``x`` and will not touch
        it after this call, so the compiled apply may donate its buffer
        to XLA.  ``prepadded=True`` says ``x`` is already bucket-shaped
        (the Batcher pads into its scratch buffer) — re-bucketing is
        skipped; bucket rounding is not idempotent for non-power-of-two
        shard counts, so the engine must not second-guess it.  The
        engine also donates buffers it padded itself: the concatenated
        copy is engine-owned by construction.

        Row-wise nets make the padding invisible: output row i depends
        only on input row i, so callers get bit-identical rows to a
        same-input synchronous ``__call__`` (tests/test_serve.py).
        """
        from repro.serve.batcher import bucket_for
        ctx = current_ctx()
        n = int(x.shape[0])
        if not prepadded:
            shards = (ctx.axis_size("data")
                      if ctx is not None and ctx.mesh is not None else 1)
            b = bucket_for(n, min_bucket, shards)
            if b != n:
                x = jnp.concatenate(
                    [x, jnp.zeros((b - n,) + x.shape[1:], x.dtype)], axis=0)
                donate = True  # the padded copy is ours, not the caller's
        if isinstance(x, jax.core.Tracer):
            donate = False  # in-trace degrade: nothing to donate
        fault = None
        if not isinstance(x, jax.core.Tracer):
            from repro.resilience.faults import FAULTS
            if FAULTS.enabled:
                # raise/stall act inside fire(); nan/inf/corrupt come back
                # as a rule for us to apply around the compute below
                fault = FAULTS.fire("engine.apply", key=self.path)
                if fault is not None and fault.mode == "corrupt":
                    # persistent until reload — drives the shadow scorer
                    # (and through it the breaker's quality trip)
                    self.params = jax.tree_util.tree_map(
                        lambda p: p + fault.scale, self.params)
        fn = self._apply_for(ctx, donate=donate)
        x = self._place(x, ctx)
        if self.tier == "int8" and not isinstance(x, jax.core.Tracer):
            from repro.obs import metrics as _m
            _m.counter("repro_quant_served_rows_total",
                       "rows served by the gated int8 tier",
                       ("bundle",)).inc(n, bundle=self.path)
        if TRACER.enabled and not isinstance(x, jax.core.Tracer):
            shape_key = (id(fn), tuple(x.shape))
            first = shape_key not in self._seen_shapes
            with TRACER.span("engine.apply", cat="engine",
                             args={"path": self.path, "rows": n,
                                   "bucket": int(x.shape[0]),
                                   "tier": self.tier,
                                   "donate": donate, "compile": first}):
                y = fn(self.params, x)
            self._seen_shapes.add(shape_key)
        else:
            y = fn(self.params, x)
        if fault is not None and fault.mode in ("nan", "inf"):
            # eager elementwise op: poisons every row while preserving
            # the output's sharding (works on global pod arrays too)
            y = y * fault.value
        # a full-bucket batch (the pod path's pre-padded global arrays)
        # skips the slice: slicing a non-addressable array outside jit
        # raises, and [:n] of n rows is the identity anyway
        return y if n == int(y.shape[0]) else y[:n]

    def infer_shape(self, in_shape):
        return self.net.out_shape()
