"""Tensor map: memory concretization (paper §IV-A, Fig. 4).

Applying a functor to application memory runs the paper's four compiler
steps, implemented here as runtime functions over JAX arrays:

  1. symbolic shape extraction — per RHS slice, the base-pointer offset and
     element count relative to the mapped ranges;
  2. symbolic shape resolution — the window shape each slice resolves to;
  3. tensor wrapping — lightweight window views (``lax.slice``, no copies
     until XLA decides layout);
  4. tensor composition — flatten + stack the per-slice views into the LHS
     tensor (app -> tensor direction only).

Direction ``to`` maps application memory -> tensor space (gather);
``from`` maps tensor space -> application memory (window writes).  The
stencil fast path is served by ``repro.kernels.stencil_gather`` on TPU;
this jnp implementation is the portable path and the kernel's oracle.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Sequence, Union

import jax
import jax.numpy as jnp

from repro.core.functor import SSlice, TensorFunctor


def _normalize_ranges(functor: TensorFunctor, ranges) -> dict:
    syms = functor.sweep_symbols
    if isinstance(ranges, dict):
        out = {}
        for k, v in ranges.items():
            if isinstance(v, range):
                out[k] = (v.start, v.stop, v.step)
            else:
                t = tuple(v)
                out[k] = t if len(t) == 3 else (t[0], t[1], 1)
        return out
    out = {}
    for s, v in zip(syms, ranges):
        t = tuple(v) if not isinstance(v, range) else (v.start, v.stop, v.step)
        out[s] = t if len(t) == 3 else (t[0], t[1], 1)
    return out


@dataclass(frozen=True)
class SliceDescriptor:
    """One RHS slice after extraction/resolution (paper's runtime struct)."""
    offsets: tuple          # per-dim start offset at the sweep origin
    window_shape: tuple     # per-dim window extent (sweep dims) or 1
    sweep_dims: tuple       # which array dim each sweep symbol drives (or None)
    elem_offsets: tuple     # per-feature additional offsets within the slice
    steps: tuple            # per-dim stride (sweep step * symbol coeff)


def symbolic_shape_extraction(group: Sequence[SSlice], ranges: dict):
    """Offsets + element counts for one RHS slice group."""
    offsets, elem_axes = [], []
    for d, s in enumerate(group):
        syms = s.start.symbols
        if len(syms) > 1:
            raise ValueError("an s-slice may use at most one s-constant")
        base = {n: ranges[n][0] for n in syms}
        offsets.append(s.start.evaluate(base))
        elem_axes.append(s.n_elements())
    return tuple(offsets), tuple(elem_axes)


def symbolic_shape_resolution(group: Sequence[SSlice], ranges: dict):
    """Window shape + sweep-dim mapping + strides for one slice group."""
    shape, sweep_dims, steps = [], [], []
    for s in group:
        syms = s.start.symbols
        if syms:
            name = syms[0]
            coeff = dict(s.start.coeffs)[name]
            lo, hi, st = ranges[name]
            n = max(0, -(-(hi - lo) // st))
            shape.append(n)
            sweep_dims.append(name)
            steps.append(st * coeff)
        else:
            shape.append(1)
            sweep_dims.append(None)
            steps.append(1)
    return tuple(shape), tuple(sweep_dims), tuple(steps)


def tensor_wrapping(group: Sequence[SSlice], ranges: dict) -> SliceDescriptor:
    offsets, elem_axes = symbolic_shape_extraction(group, ranges)
    shape, sweep_dims, steps = symbolic_shape_resolution(group, ranges)
    elem_offsets = tuple(itertools.product(
        *[range(0, n * max(1, s.step), max(1, s.step)) if n > 1 else (0,)
          for n, s in zip(elem_axes, group)]))
    return SliceDescriptor(offsets, shape, sweep_dims, elem_offsets, steps)


def _gather_group(array, desc: SliceDescriptor):
    """All shifted windows for one slice group -> [sweep..., n_elem]."""
    views = []
    for eo in desc.elem_offsets:
        starts, limits, strides = [], [], []
        for d in range(len(desc.offsets)):
            start = desc.offsets[d] + eo[d]
            extent = desc.window_shape[d]
            step = desc.steps[d] if desc.sweep_dims[d] is not None else 1
            starts.append(start)
            limits.append(start + (extent - 1) * abs(step) + 1 if extent > 1
                          else start + 1)
            strides.append(abs(step) if extent > 1 else 1)
        v = jax.lax.slice(array, starts, limits, strides)
        views.append(v.reshape([s for s in v.shape if s != 1] or [1]))
    return jnp.stack(views, axis=-1)


class TensorMap:
    """A functor applied to concrete memory over concrete ranges."""

    def __init__(self, functor: TensorFunctor, array, ranges,
                 direction: str = "to"):
        assert direction in ("to", "from")
        self.functor = functor
        self.array = array
        self.ranges = _normalize_ranges(functor, ranges)
        self.direction = direction
        self.descriptors = [tensor_wrapping(g, self.ranges)
                            for g in functor.rhs]

    # ------------------------------------------------------ to tensor -----
    def to_tensor(self, array=None):
        """Tensor composition: app memory -> LHS-shaped tensor."""
        array = self.array if array is None else array
        parts = [_gather_group(array, d) for d in self.descriptors]
        t = jnp.concatenate(parts, axis=-1)
        return self._compose_lhs(t)

    def _lhs_dims(self):
        sweep, feat = [], []
        for s in self.functor.lhs:
            if s.start.symbols:
                name = s.start.symbols[0]
                lo, hi, st = self.ranges[name]
                sweep.append(max(0, -(-(hi - lo) // st)))
            else:
                feat.append(s.n_elements())
        return sweep, feat

    def _compose_lhs(self, t):
        sweep, feat = self._lhs_dims()
        want_feat = 1
        for f in feat:
            want_feat *= f
        if t.shape[-1] != want_feat:
            raise ValueError(
                f"functor {self.functor.name}: LHS declares {want_feat} "
                f"features, RHS provides {t.shape[-1]}")
        return t.reshape(tuple(sweep) + tuple(feat) if feat else tuple(sweep)
                         + (1,))[..., 0] if not feat else \
            t.reshape(tuple(sweep) + tuple(feat))

    @property
    def tensor_shape(self):
        sweep, feat = self._lhs_dims()
        return tuple(sweep) + tuple(feat if feat else ())

    # ---------------------------------------------------- from tensor -----
    def from_tensor(self, tensor, array=None):
        """Write the tensor back through the functor windows (scatter)."""
        array = self.array if array is None else array
        sweep, feat = self._lhs_dims()
        flat = tensor.reshape(tuple(sweep) + (-1,))
        fidx = 0
        out = array
        for desc in self.descriptors:
            for eo in desc.elem_offsets:
                starts = [desc.offsets[d] + eo[d]
                          for d in range(len(desc.offsets))]
                piece = flat[..., fidx]
                shape = [desc.window_shape[d] for d in range(len(starts))]
                piece = piece.reshape(shape)
                out = jax.lax.dynamic_update_slice(
                    out, piece.astype(out.dtype), tuple(starts))
                fidx += 1
        return out

    def min_array_shape(self):
        """Smallest app-memory shape the windows cover (template synth)."""
        nd = len(self.descriptors[0].offsets)
        hi = [0] * nd
        for desc in self.descriptors:
            for eo in desc.elem_offsets:
                for d in range(nd):
                    step = abs(desc.steps[d]) if desc.sweep_dims[d] else 1
                    end = (desc.offsets[d] + eo[d]
                           + (desc.window_shape[d] - 1) * step + 1)
                    hi[d] = max(hi[d], end)
        return tuple(hi)

    def __repr__(self):
        return (f"TensorMap({self.functor.name}, dir={self.direction}, "
                f"ranges={self.ranges}, tensor_shape={self.tensor_shape})")
