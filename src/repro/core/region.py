"""Execution control: the ``approx ml`` region (paper §III, §IV-B).

``MLRegion`` wraps the *accurate execution path* (a JAX-traceable function)
and, per the paper's three ml-modes:

  * ``collect``    — run the accurate path, bridge its inputs/outputs to
                     tensor space, and append (inputs, outputs, runtime) to
                     the SurrogateDB group of this region;
  * ``infer``      — replace the region with surrogate inference through
                     the data bridge;
  * ``predicated`` — a runtime boolean picks the path per invocation; both
                     execution paths live in the same traced program
                     (``lax.cond``), the JAX analogue of HPAC's dual
                     execution paths in one binary;
  * ``infer_async``— (serving extension) enqueue the bridged rows on a
                     ``repro.serve.ServeQueue`` and return an
                     :class:`AsyncRegionResult`; many callers' requests
                     coalesce into one mesh-wide batch before inference.

A ``serving=`` queue can also be attached to a ``predicated`` region: the
eager ML path then defers through the queue (both branches return
:class:`AsyncRegionResult` so the caller's interface is uniform), while
traced calls keep the synchronous in-program ``lax.cond``.

Eager calls are host-timed exactly; calls inside a jit trace fall back to
ordered ``io_callback`` timing/persistence (documented approximation).
"""
from __future__ import annotations

import functools
import time
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import io_callback

from repro.core.database import SurrogateDB
from repro.core.engine import InferenceEngine
from repro.core.functor import TensorFunctor
from repro.core.tensor_map import TensorMap
from repro.obs import TRACER
from repro.obs.quality import SHADOW
from repro.resilience.breaker import BREAKERS


def _is_traced(*arrays):
    return any(isinstance(x, jax.core.Tracer)
               for a in arrays for x in jax.tree.leaves(a))


class AsyncRegionResult:
    """Deferred region invocation handle (``infer_async`` / serving).

    ``result()`` blocks on the serve future (flushing on demand when the
    queue has no dispatcher thread) and runs the output data bridge in
    the caller's thread — so bridging cost is paid by whoever consumes
    the result, not by the dispatcher.
    """

    __slots__ = ("_region", "_arrays", "_future", "_done")

    def __init__(self, region, arrays, future=None, resolved=None):
        self._region, self._arrays = region, arrays
        self._future = future
        self._done = resolved  # pre-resolved outputs (accurate path)

    def done(self) -> bool:
        return self._done is not None or self._future.done()

    def deferred(self) -> bool:
        """True when this invocation actually went through the queue."""
        return self._future is not None

    def result(self, timeout: Optional[float] = None) -> dict:
        if self._done is None:
            try:
                Y = self._future.result(timeout)
            except TimeoutError:
                raise  # not a surrogate failure: the caller set the budget
            except Exception:
                # zero-lost contract: a failed dispatch (injected fault,
                # non-finite screen, dead dispatcher) degrades to the
                # accurate path instead of surfacing the serve error
                region = self._region
                if not (BREAKERS.enabled and region.model_path):
                    raise
                BREAKERS.record_failure(region.model_path)
                self._done = region._fallback(self._arrays, "result")
                return self._done
            self._done = self._region._bridge_from_jit(Y, self._arrays)
        return self._done


class MLRegion:
    def __init__(self, name: str, fn: Callable, *,
                 inputs: Dict[str, Tuple[TensorFunctor, dict]],
                 outputs: Dict[str, Tuple[TensorFunctor, dict]],
                 mode: str = "predicated",
                 model: Optional[str] = None,
                 database: Optional[str] = None,
                 serving=None):
        assert mode in ("collect", "infer", "predicated", "infer_async")
        self.name, self.fn, self.mode = name, fn, mode
        self.inputs, self.outputs = inputs, outputs
        self.model_path = model
        self.serving = serving  # repro.serve.ServeQueue (or None)
        if mode == "infer_async":
            assert serving is not None, \
                f"region {name}: mode='infer_async' needs a serving= queue"
        self.db = (database if isinstance(database, SurrogateDB)
                   else SurrogateDB(database)) if database else None

    # ------------------------------------------------------ data bridge ---
    def bridge_in(self, arrays: dict):
        """App memory -> model input tensor [sweep..., features]."""
        parts = []
        for name, (functor, ranges) in self.inputs.items():
            tm = TensorMap(functor, arrays[name], ranges, "to")
            parts.append(tm.to_tensor())
        t = parts[0] if len(parts) == 1 else jnp.concatenate(
            [p.reshape(p.shape[:1] + (-1,)) if p.ndim > 1 else p[:, None]
             for p in parts], axis=-1)
        return t

    def bridge_out_tensors(self, out_arrays: dict):
        parts = []
        for name, (functor, ranges) in self.outputs.items():
            tm = TensorMap(functor, out_arrays[name], ranges, "to")
            parts.append(tm.to_tensor())
        return parts[0] if len(parts) == 1 else jnp.concatenate(
            [p.reshape(p.shape[:1] + (-1,)) for p in parts], axis=-1)

    # the bridges are pure gather/scatter/reshape programs over static
    # functor descriptors, so one jit per region collapses their eager
    # op-by-op dispatch (which dominated small per-call serving) into a
    # single compiled call — bit-identical, no float arithmetic involved
    @functools.cached_property
    def _bridge_in_jit(self):
        return jax.jit(self.bridge_in)

    @functools.cached_property
    def _bridge_from_jit(self):
        return jax.jit(self.bridge_from)

    def bridge_from(self, tensor, arrays: dict):
        """Model output tensor -> app memory (through the out functors).

        Pure outputs (not also region inputs) get a synthesized zero
        template covering exactly the functor's written window.
        """
        out = {}
        offset = 0
        for name, (functor, ranges) in self.outputs.items():
            if name in arrays:
                template = arrays[name]
            else:
                probe = TensorMap(functor, None, ranges, "from")
                template = jnp.zeros(probe.min_array_shape(), tensor.dtype)
            tm = TensorMap(functor, template, ranges, "from")
            want = tm.tensor_shape
            n = int(np.prod(want[len(want) - _feat_dims(tm):])) if want else 1
            if len(self.outputs) == 1:
                piece = tensor.reshape(want)
            else:
                flatfeat = tensor.reshape(tensor.shape[0], -1)
                piece = flatfeat[:, offset:offset + n].reshape(want)
                offset += n
            out[name] = tm.from_tensor(piece)
        return out

    # ------------------------------------------------------- execution ----
    def engine(self) -> InferenceEngine:
        assert self.model_path, f"region {self.name}: no model path"
        # always resolve through the process-wide cache: get() is a dict
        # lookup + bundle-mtime stat, and it is what reloads a bundle the
        # NAS loop retrained under this region's feet
        return InferenceEngine.get(self.model_path)

    def _rows_in(self, arrays: dict):
        """Bridge app arrays to engine-shaped f32 rows [n, *in_shape[1:]]."""
        X = self._bridge_in_jit(arrays)
        eng = self.engine()
        in_shape = tuple(eng.spec["in_shape"])
        return eng, X.reshape((-1,) + in_shape[1:]).astype(jnp.float32)

    def _fallback(self, arrays: dict, path: str) -> dict:
        """Serve this invocation from the accurate path (breaker OPEN or
        a dispatch failure), wearing the surrogate's output contract."""
        BREAKERS.note_fallback(self.model_path, path)
        with TRACER.span("resilience.fallback", cat="region",
                         args={"region": self.name, "key": self.model_path,
                               "path": path}):
            return self._accurate(arrays, collect=False)

    def _infer(self, arrays: dict):
        traced = _is_traced(arrays)
        use_breaker = (BREAKERS.enabled and self.model_path is not None
                       and not traced)
        if use_breaker and not BREAKERS.allow(self.model_path):
            return self._fallback(arrays, "infer")
        try:
            eng, Xb = self._rows_in(arrays)
            Y = eng(Xb)
        except Exception:
            if not use_breaker:
                raise
            BREAKERS.record_failure(self.model_path)
            return self._fallback(arrays, "infer")
        if use_breaker:
            BREAKERS.record_success(self.model_path)
        if SHADOW.enabled and not _is_traced(arrays, Xb) and SHADOW.sample():
            self._shadow_submit(arrays, rows=int(Xb.shape[0]), Y=Y)
        return self._bridge_from_jit(Y, arrays)

    def _infer_async(self, arrays: dict) -> AsyncRegionResult:
        """Enqueue this invocation on the serve queue, keyed (multiplexed)
        by bundle path; inside a trace there is no host queue to park rows
        on, so traced calls degrade to synchronous inference."""
        if _is_traced(arrays):
            return AsyncRegionResult(self, arrays,
                                     resolved=self._infer(arrays))
        if (BREAKERS.enabled and self.model_path is not None
                and not BREAKERS.allow(self.model_path)):
            # breaker OPEN (or HALF_OPEN non-probe): resolve through the
            # accurate path immediately, same handle contract
            return AsyncRegionResult(
                self, arrays,
                resolved=self._fallback(arrays, "infer_async"))
        eng, Xb = self._rows_in(arrays)
        del eng  # resolved for bundle load/reload; batcher re-gets per batch
        fut = self.serving.submit(self.model_path, Xb)
        if SHADOW.enabled and SHADOW.sample():
            self._shadow_submit(arrays, rows=int(Xb.shape[0]), future=fut)
        return AsyncRegionResult(self, arrays, future=fut)

    def _shadow_submit(self, arrays: dict, *, rows: int, Y=None,
                       future=None) -> None:
        """Capture this sampled invocation for background accuracy
        scoring: the surrogate's output rows vs the accurate function's
        bridged output over a *snapshot* of the inputs (the app may
        mutate its buffers after the region returns).  The accurate
        replay runs later on the scorer's worker thread — never here."""
        snap = {k: np.array(v) for k, v in arrays.items()}
        if future is not None:
            pred = lambda: np.asarray(future.result(60.0))  # noqa: E731
            trace = future.trace
        else:
            pred = lambda: np.asarray(Y)  # noqa: E731
            trace = None

        def ref():
            return np.asarray(self.bridge_out_tensors(self.fn(**snap)))

        SHADOW.submit(self.model_path, pred=pred, ref=ref,
                      region=self.name, rows=rows, trace=trace)

    def _n_sweep(self) -> int:
        functor = next(iter(self.inputs.values()))[0]
        return len(functor.sweep_symbols)

    def _rows(self, X):
        """DB row layout (paper §V-B): outer dim = unique data identifier.

        One sweep dim (e.g. pose/option index): each sweep entry is a row.
        Spatial sweeps (stencils): the whole tensor is one row.
        """
        X = np.asarray(X)
        if self._n_sweep() <= 1:
            return X.reshape(X.shape[0], -1) if X.ndim > 1 else X[:, None]
        return X[None]

    def _accurate(self, arrays: dict, collect: bool):
        if collect and not _is_traced(arrays):
            # eager: exact wall-clock of the accurate path (paper Table III)
            X = np.asarray(self.bridge_in(arrays))
            t0 = time.perf_counter()
            outs = self.fn(**arrays)
            jax.block_until_ready(outs)
            dt = time.perf_counter() - t0
            Y = np.asarray(self.bridge_out_tensors(outs))
            self.db.group(self.name).append(self._rows(X), self._rows(Y), dt)
            return outs
        outs = self.fn(**arrays)
        if collect:
            X = self.bridge_in(arrays)
            Y = self.bridge_out_tensors(outs)

            def tap(xv, yv):
                self.db.group(self.name).append(self._rows(xv),
                                                self._rows(yv), float("nan"))
                return np.int32(0)

            io_callback(tap, jax.ShapeDtypeStruct((), jnp.int32), X, Y,
                        ordered=True)
        return outs

    def __call__(self, predicate=None, **arrays):
        mode = self.mode
        if mode == "collect":
            return self._accurate(arrays, collect=True)
        if mode == "infer":
            return self._infer(arrays)
        if mode == "infer_async":
            return self._infer_async(arrays)
        # predicated: true -> inference, false -> accurate (+collection)
        assert predicate is not None, "predicated region needs a predicate"
        if not _is_traced(arrays) and not isinstance(predicate, jax.core.Tracer):
            if self.serving is not None:
                # serving hook: the ML path defers through the queue; the
                # accurate path resolves immediately but wears the same
                # handle so callers need not branch on the predicate
                if bool(predicate):
                    return self._infer_async(arrays)
                return AsyncRegionResult(
                    self, arrays,
                    resolved=self._accurate(arrays,
                                            collect=self.db is not None))
            return (self._infer(arrays) if bool(predicate)
                    else self._accurate(arrays, collect=self.db is not None))
        # traced: both paths in one program
        names = list(self.outputs.keys())

        def t_inf(arr):
            return tuple(self._infer(arr)[n] for n in names)

        def t_acc(arr):
            outs = self.fn(**arr)
            return tuple(outs[n] for n in names)

        res = jax.lax.cond(predicate, t_inf, t_acc, arrays)
        return dict(zip(names, res))


def _feat_dims(tm: TensorMap) -> int:
    _, feat = tm._lhs_dims()
    return len(feat)


def approx_ml(fn=None, **kw) -> MLRegion:
    """Factory mirroring the ``#pragma approx ml(...)`` clause."""
    name = kw.pop("name", getattr(fn, "__name__", "region"))
    return MLRegion(name, fn, **kw)
