"""Execution control: the ``approx ml`` region (paper §III, §IV-B).

``MLRegion`` wraps the *accurate execution path* (a JAX-traceable function)
and, per the paper's three ml-modes:

  * ``collect``    — run the accurate path, bridge its inputs/outputs to
                     tensor space, and append (inputs, outputs, runtime) to
                     the SurrogateDB group of this region;
  * ``infer``      — replace the region with surrogate inference through
                     the data bridge;
  * ``predicated`` — a runtime boolean picks the path per invocation; both
                     execution paths live in the same traced program
                     (``lax.cond``), the JAX analogue of HPAC's dual
                     execution paths in one binary.

Eager calls are host-timed exactly; calls inside a jit trace fall back to
ordered ``io_callback`` timing/persistence (documented approximation).
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import io_callback

from repro.core.database import SurrogateDB
from repro.core.engine import InferenceEngine
from repro.core.functor import TensorFunctor
from repro.core.tensor_map import TensorMap


def _is_traced(*arrays):
    return any(isinstance(x, jax.core.Tracer)
               for a in arrays for x in jax.tree.leaves(a))


class MLRegion:
    def __init__(self, name: str, fn: Callable, *,
                 inputs: Dict[str, Tuple[TensorFunctor, dict]],
                 outputs: Dict[str, Tuple[TensorFunctor, dict]],
                 mode: str = "predicated",
                 model: Optional[str] = None,
                 database: Optional[str] = None):
        assert mode in ("collect", "infer", "predicated")
        self.name, self.fn, self.mode = name, fn, mode
        self.inputs, self.outputs = inputs, outputs
        self.model_path = model
        self.db = (database if isinstance(database, SurrogateDB)
                   else SurrogateDB(database)) if database else None
        self._engine: Optional[InferenceEngine] = None

    # ------------------------------------------------------ data bridge ---
    def bridge_in(self, arrays: dict):
        """App memory -> model input tensor [sweep..., features]."""
        parts = []
        for name, (functor, ranges) in self.inputs.items():
            tm = TensorMap(functor, arrays[name], ranges, "to")
            parts.append(tm.to_tensor())
        t = parts[0] if len(parts) == 1 else jnp.concatenate(
            [p.reshape(p.shape[:1] + (-1,)) if p.ndim > 1 else p[:, None]
             for p in parts], axis=-1)
        return t

    def bridge_out_tensors(self, out_arrays: dict):
        parts = []
        for name, (functor, ranges) in self.outputs.items():
            tm = TensorMap(functor, out_arrays[name], ranges, "to")
            parts.append(tm.to_tensor())
        return parts[0] if len(parts) == 1 else jnp.concatenate(
            [p.reshape(p.shape[:1] + (-1,)) for p in parts], axis=-1)

    def bridge_from(self, tensor, arrays: dict):
        """Model output tensor -> app memory (through the out functors).

        Pure outputs (not also region inputs) get a synthesized zero
        template covering exactly the functor's written window.
        """
        out = {}
        offset = 0
        for name, (functor, ranges) in self.outputs.items():
            if name in arrays:
                template = arrays[name]
            else:
                probe = TensorMap(functor, None, ranges, "from")
                template = jnp.zeros(probe.min_array_shape(), tensor.dtype)
            tm = TensorMap(functor, template, ranges, "from")
            want = tm.tensor_shape
            n = int(np.prod(want[len(want) - _feat_dims(tm):])) if want else 1
            if len(self.outputs) == 1:
                piece = tensor.reshape(want)
            else:
                flatfeat = tensor.reshape(tensor.shape[0], -1)
                piece = flatfeat[:, offset:offset + n].reshape(want)
                offset += n
            out[name] = tm.from_tensor(piece)
        return out

    # ------------------------------------------------------- execution ----
    def engine(self) -> InferenceEngine:
        assert self.model_path, f"region {self.name}: no model path"
        # always resolve through the process-wide cache: get() is a dict
        # lookup + bundle-mtime stat, and it is what reloads a bundle the
        # NAS loop retrained under this region's feet
        self._engine = InferenceEngine.get(self.model_path)
        return self._engine

    def _infer(self, arrays: dict):
        X = self.bridge_in(arrays)
        eng = self.engine()
        in_shape = tuple(eng.spec["in_shape"])
        Xb = X.reshape((-1,) + in_shape[1:])
        Y = eng(Xb.astype(jnp.float32))
        return self.bridge_from(Y, arrays)

    def _n_sweep(self) -> int:
        functor = next(iter(self.inputs.values()))[0]
        return len(functor.sweep_symbols)

    def _rows(self, X):
        """DB row layout (paper §V-B): outer dim = unique data identifier.

        One sweep dim (e.g. pose/option index): each sweep entry is a row.
        Spatial sweeps (stencils): the whole tensor is one row.
        """
        X = np.asarray(X)
        if self._n_sweep() <= 1:
            return X.reshape(X.shape[0], -1) if X.ndim > 1 else X[:, None]
        return X[None]

    def _accurate(self, arrays: dict, collect: bool):
        if collect and not _is_traced(arrays):
            # eager: exact wall-clock of the accurate path (paper Table III)
            X = np.asarray(self.bridge_in(arrays))
            t0 = time.perf_counter()
            outs = self.fn(**arrays)
            jax.block_until_ready(outs)
            dt = time.perf_counter() - t0
            Y = np.asarray(self.bridge_out_tensors(outs))
            self.db.group(self.name).append(self._rows(X), self._rows(Y), dt)
            return outs
        outs = self.fn(**arrays)
        if collect:
            X = self.bridge_in(arrays)
            Y = self.bridge_out_tensors(outs)

            def tap(xv, yv):
                self.db.group(self.name).append(self._rows(xv),
                                                self._rows(yv), float("nan"))
                return np.int32(0)

            io_callback(tap, jax.ShapeDtypeStruct((), jnp.int32), X, Y,
                        ordered=True)
        return outs

    def __call__(self, predicate=None, **arrays):
        mode = self.mode
        if mode == "collect":
            return self._accurate(arrays, collect=True)
        if mode == "infer":
            return self._infer(arrays)
        # predicated: true -> inference, false -> accurate (+collection)
        assert predicate is not None, "predicated region needs a predicate"
        if not _is_traced(arrays) and not isinstance(predicate, jax.core.Tracer):
            return (self._infer(arrays) if bool(predicate)
                    else self._accurate(arrays, collect=self.db is not None))
        # traced: both paths in one program
        names = list(self.outputs.keys())

        def t_inf(arr):
            return tuple(self._infer(arr)[n] for n in names)

        def t_acc(arr):
            outs = self.fn(**arr)
            return tuple(outs[n] for n in names)

        res = jax.lax.cond(predicate, t_inf, t_acc, arrays)
        return dict(zip(names, res))


def _feat_dims(tm: TensorMap) -> int:
    _, feat = tm._lhs_dims()
    return len(feat)


def approx_ml(fn=None, **kw) -> MLRegion:
    """Factory mirroring the ``#pragma approx ml(...)`` clause."""
    name = kw.pop("name", getattr(fn, "__name__", "region"))
    return MLRegion(name, fn, **kw)
