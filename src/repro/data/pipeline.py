"""Deterministic, seekable data pipeline.

``batch_at(step)`` is a pure function of (seed, step, host slice): restart
or elastic re-scale replays nothing and skips nothing — the data order is
identical whether a step is produced before or after a failure, and a
re-sharded job (different dp_rank/dp_size split) still covers the global
batch exactly once.
"""
from __future__ import annotations

import numpy as np


class TokenPipeline:
    """Synthetic LM token stream (markov-ish mixture so loss can fall)."""

    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 seed: int = 0, dp_rank: int = 0, dp_size: int = 1):
        assert global_batch % dp_size == 0
        self.V, self.S = vocab_size, seq_len
        self.B = global_batch
        self.local_B = global_batch // dp_size
        self.rank, self.size = dp_rank, dp_size
        self.seed = seed

    def batch_at(self, step: int):
        """Returns dict(tokens, targets) for this host's slice of `step`."""
        lo = self.rank * self.local_B
        rows = [self._row(step, lo + i) for i in range(self.local_B)]
        toks = np.stack(rows)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "targets": toks[:, 1:].astype(np.int32)}

    def _row(self, step: int, row: int):
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, row]))
        # structured stream: arithmetic progressions + noise -> learnable
        start = rng.integers(0, self.V)
        stride = rng.integers(1, 7)
        seq = (start + stride * np.arange(self.S + 1)) % self.V
        noise = rng.random(self.S + 1) < 0.1
        seq = np.where(noise, rng.integers(0, self.V, self.S + 1), seq)
        return seq

    def reshard(self, dp_rank: int, dp_size: int):
        """Elastic re-split: same global order, new host slice."""
        return TokenPipeline(self.V, self.S, self.B, self.seed,
                             dp_rank, dp_size)
