"""Fault-tolerant checkpointing.

* atomic: write to ``step_XXXX.tmp`` then rename — a crash mid-write never
  corrupts the latest checkpoint;
* keep-k rotation;
* async: the device->host gather happens on the caller thread (cheap), the
  file write runs on a background writer thread;
* **elastic re-shard on load**: checkpoints store global arrays + the tree
  structure, so ``restore`` lays the state onto whatever mesh/sharding the
  *current* job runs with (different host/chip count than the writer) —
  node-failure recovery onto a smaller or larger slice.

Bitwise-exact resume is tested in tests/test_checkpoint.py.
"""
from __future__ import annotations

import json
import pathlib
import re
import shutil
import threading

import jax
import numpy as np


def _paths_and_leaves(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                     for p in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return keys, leaves, treedef


class CheckpointManager:
    def __init__(self, directory, keep: int = 3, async_write: bool = True):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_write = async_write
        self._thread = None

    # ---------------------------------------------------------- save ------
    def save(self, step: int, state) -> None:
        keys, leaves, _ = _paths_and_leaves(state)
        host = [np.asarray(x) for x in leaves]  # gather to host (global)
        self.wait()
        if self.async_write:
            self._thread = threading.Thread(
                target=self._write, args=(step, keys, host), daemon=True)
            self._thread.start()
        else:
            self._write(step, keys, host)

    def _write(self, step, keys, host):
        tmp = self.dir / f"step_{step:08d}.tmp"
        final = self.dir / f"step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        np.savez(tmp / "arrays.npz",
                 **{f"a{i}": a for i, a in enumerate(host)})
        (tmp / "meta.json").write_text(json.dumps(
            {"step": step, "keys": keys,
             "dtypes": [str(a.dtype) for a in host]}))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # --------------------------------------------------------- restore ----
    def all_steps(self):
        out = []
        for p in self.dir.iterdir():
            m = re.fullmatch(r"step_(\d+)", p.name)
            if m and (p / "meta.json").exists():
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self):
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, state_like, step: int | None = None, shardings=None):
        """Restore into the structure of ``state_like``.

        ``shardings``: optional matching pytree of NamedShardings — the
        elastic path: arrays are laid out for the *current* mesh regardless
        of the topology that wrote the checkpoint.
        """
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self.dir / f"step_{step:08d}"
        meta = json.loads((d / "meta.json").read_text())
        z = np.load(d / "arrays.npz")
        by_key = {k: z[f"a{i}"] for i, k in enumerate(meta["keys"])}
        keys, leaves, treedef = _paths_and_leaves(state_like)
        shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                        else [None] * len(leaves))
        dt_by_key = dict(zip(meta["keys"], meta["dtypes"]))
        out = []
        for k, ref, sh in zip(keys, leaves, shard_leaves):
            a = by_key[k]
            if a.dtype.kind == "V":  # npz round-trips bf16 as raw void16
                a = a.view(np.dtype(dt_by_key[k]))
            arr = jax.numpy.asarray(a, dtype=ref.dtype)
            if sh is not None:
                arr = jax.device_put(arr, sh)
            out.append(arr)
        return jax.tree_util.tree_unflatten(treedef, out), step
