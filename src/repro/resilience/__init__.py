"""repro.resilience — fault tolerance for the serving stack.

Three pieces, layered under `repro.serve` and `repro.core.region`:

- :mod:`repro.resilience.faults` — deterministic, seedable fault
  injection (`REPRO_FAULTS`) at fixed serve-path sites, used by tests,
  benches, and the chaos CI lane.
- :mod:`repro.resilience.retry` — capped exponential backoff policy for
  transient dispatch failures.
- :mod:`repro.resilience.breaker` — per-bundle CLOSED→OPEN→HALF_OPEN
  circuit breakers that route `MLRegion` traffic to the accurate path
  while the surrogate is failing or drifted.

Import order matters: this package imports only `repro.obs`; the serve
and region layers import us.
"""
from repro.resilience.faults import (  # noqa: F401
    FAULTS, FaultInjector, FaultRule, InjectedFault, parse_plan)
from repro.resilience.retry import DEFAULT_RETRY, RetryPolicy  # noqa: F401
from repro.resilience.breaker import (  # noqa: F401
    BREAKERS, BreakerBoard, BreakerPolicy, CircuitBreaker,
    CLOSED, OPEN, HALF_OPEN)

__all__ = [
    "FAULTS", "FaultInjector", "FaultRule", "InjectedFault", "parse_plan",
    "DEFAULT_RETRY", "RetryPolicy",
    "BREAKERS", "BreakerBoard", "BreakerPolicy", "CircuitBreaker",
    "CLOSED", "OPEN", "HALF_OPEN",
]
