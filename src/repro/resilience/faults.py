"""Deterministic fault injection for the serving stack.

A :class:`FaultPlan` is a seedable set of rules parsed from the
``REPRO_FAULTS`` env var (or installed programmatically via
:meth:`FaultInjector.configure`); the process-wide :data:`FAULTS`
injector evaluates them at four fixed sites on the serve path:

========================  ====================================================
site                      where it fires
========================  ====================================================
``engine.apply``          ``InferenceEngine.apply_batched`` (per batch)
``kernel.dispatch``       ``repro.kernels.registry.dispatch`` (per trace)
``batcher.scatter``       ``Batcher.dispatch`` after device->host, pre-scatter
``pod.flush``             ``ServeQueue.pod_flush`` entry, before the heartbeat
========================  ====================================================

Spec grammar (``;``-separated rules)::

    site:mode[:k=v[,k=v...]]

modes: ``raise`` (raise :class:`InjectedFault`), ``nan`` / ``inf``
(poison output rows), ``stall`` (sleep ``stall`` seconds), ``corrupt``
(perturb the engine's resident weights by ``scale``), ``drop``
(simulate a dropped host: stall ``stall`` seconds, default 3600).

triggers (all optional, combinable): ``after=N`` (skip the first N
matching calls), ``every=N`` (then fire each Nth), ``n=N`` (at most N
fires), ``p=F`` with ``seed=S`` (seeded Bernoulli — deterministic
across runs), ``pid=K`` (only in pod process K, from
``REPRO_PROCESS_ID``), ``key=SUBSTR`` (only for keys containing it).

Examples::

    REPRO_FAULTS="engine.apply:raise:after=3,n=2"
    REPRO_FAULTS="batcher.scatter:nan:every=2"
    REPRO_FAULTS="pod.flush:drop:pid=1,stall=20"

Disabled (no rules) the injector costs one attribute read at each site
(``FAULTS.enabled`` is checked by the call sites themselves), so the
production hot path pays nothing.  Imports only stdlib + numpy +
``repro.obs.metrics`` — safe at any layer, pre-bootstrap included.
"""
from __future__ import annotations

import os
import time
from typing import Dict, List, Optional

import numpy as np

from repro.obs import metrics as _metrics

ENV_FAULTS = "REPRO_FAULTS"

SITES = ("engine.apply", "kernel.dispatch", "batcher.scatter", "pod.flush")
MODES = ("raise", "nan", "inf", "stall", "corrupt", "drop")

_INJECTED = _metrics.counter(
    "repro_resilience_faults_injected_total",
    "faults fired by the injection harness", ("site", "mode"))


class InjectedFault(RuntimeError):
    """Raised by a ``raise``-mode rule; carries ``site`` and ``key``."""

    def __init__(self, site: str, key: Optional[str] = None):
        super().__init__(f"injected fault at {site}"
                         + (f" (key={key})" if key else ""))
        self.site, self.key = site, key


class FaultRule:
    """One parsed ``site:mode:params`` rule with its trigger state."""

    __slots__ = ("site", "mode", "params", "after", "every", "max_fires",
                 "p", "pid", "key_substr", "stall_s", "scale", "value",
                 "_calls", "_fires", "_rng")

    def __init__(self, site: str, mode: str, params: Dict[str, str]):
        if site not in SITES:
            raise ValueError(f"fault rule: unknown site {site!r} "
                             f"(known: {', '.join(SITES)})")
        if mode not in MODES:
            raise ValueError(f"fault rule: unknown mode {mode!r} "
                             f"(known: {', '.join(MODES)})")
        self.site, self.mode = site, mode
        self.params = dict(params)
        self.after = int(params.get("after", 0))
        self.every = int(params.get("every", 1))
        self.max_fires = int(params.get("n", 0)) or None
        self.p = float(params.get("p", 1.0))
        self.pid = int(params["pid"]) if "pid" in params else None
        self.key_substr = params.get("key")
        self.stall_s = float(params.get(
            "stall", 3600.0 if mode == "drop" else 0.25))
        self.scale = float(params.get("scale", 0.5))
        self.value = np.float32("nan" if mode != "inf" else "inf")
        self._calls = 0
        self._fires = 0
        # seeded per rule: same spec -> same fire pattern, every run
        self._rng = np.random.default_rng(int(params.get("seed", 0)))

    def matches(self, site: str, key: Optional[str]) -> bool:
        if site != self.site:
            return False
        if self.key_substr and (key is None or self.key_substr not in key):
            return False
        if self.pid is not None:
            env_pid = os.environ.get("REPRO_PROCESS_ID")
            if env_pid is None or int(env_pid) != self.pid:
                return False
        return True

    def fires(self) -> bool:
        """Advance this rule's trigger state for one matching call."""
        self._calls += 1
        if self._calls <= self.after:
            return False
        if (self._calls - self.after - 1) % max(1, self.every):
            return False
        if self.max_fires is not None and self._fires >= self.max_fires:
            return False
        if self.p < 1.0 and self._rng.random() >= self.p:
            return False
        self._fires += 1
        return True

    def snapshot(self) -> dict:
        return {"site": self.site, "mode": self.mode,
                "calls": self._calls, "fires": self._fires,
                "params": dict(self.params)}


def parse_plan(spec: str) -> List[FaultRule]:
    """Parse a ``REPRO_FAULTS`` spec string into rules."""
    rules = []
    for part in (spec or "").split(";"):
        part = part.strip()
        if not part:
            continue
        bits = part.split(":", 2)
        if len(bits) < 2:
            raise ValueError(f"fault rule {part!r}: want site:mode[:k=v,..]")
        params: Dict[str, str] = {}
        if len(bits) == 3 and bits[2]:
            for kv in bits[2].split(","):
                k, _, v = kv.partition("=")
                if not _ :
                    raise ValueError(f"fault rule {part!r}: bad param {kv!r}")
                params[k.strip()] = v.strip()
        rules.append(FaultRule(bits[0].strip(), bits[1].strip(), params))
    return rules


class FaultInjector:
    """Process-wide fault plan.  ``enabled`` is False with no rules, and
    call sites guard on it, so disabled injection is one attribute read."""

    def __init__(self, spec: Optional[str] = None):
        self.rules: List[FaultRule] = []
        self.enabled = False
        if spec:
            self.configure(spec)

    def configure(self, spec: Optional[str]) -> "FaultInjector":
        self.rules = parse_plan(spec) if spec else []
        self.enabled = bool(self.rules)
        return self

    def clear(self) -> None:
        self.rules = []
        self.enabled = False

    def fire(self, site: str, key: Optional[str] = None
             ) -> Optional[FaultRule]:
        """Evaluate ``site``; raise/stall modes act here, output-shaping
        modes (``nan``/``inf``/``corrupt``) return the rule for the call
        site to apply.  Returns None when nothing fired."""
        if not self.enabled:
            return None
        for rule in self.rules:
            if not rule.matches(site, key):
                continue
            if not rule.fires():
                continue
            _INJECTED.inc(1, site=site, mode=rule.mode)
            if rule.mode == "raise":
                raise InjectedFault(site, key)
            if rule.mode in ("stall", "drop"):
                time.sleep(rule.stall_s)
                return rule
            return rule
        return None

    def snapshot(self) -> dict:
        return {"enabled": self.enabled,
                "rules": [r.snapshot() for r in self.rules]}


#: process-wide injector, armed from the environment at import
FAULTS = FaultInjector(os.environ.get(ENV_FAULTS) or None)


def get_faults() -> FaultInjector:
    return FAULTS
