"""Retry policy for transient dispatch failures.

Capped exponential backoff with deterministic-seedable jitter.  The
batcher retries the gather→apply→to_host pipeline under this policy
before failing futures; engine/bundle *load* failures never retry (they
are deterministic, not transient — see ``Batcher.dispatch``).
"""
from __future__ import annotations

import dataclasses
import random
from typing import Optional


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Capped exponential backoff: delay(k) = min(base * 2**k, max) with
    up to ``jitter`` fractional randomization to decorrelate retries."""

    max_attempts: int = 3
    base_delay_s: float = 0.01
    max_delay_s: float = 0.25
    jitter: float = 0.5
    seed: Optional[int] = None

    def delay_for(self, attempt: int, rng: Optional[random.Random] = None
                  ) -> float:
        """Backoff delay after failed attempt ``attempt`` (0-indexed)."""
        d = min(self.base_delay_s * (2.0 ** attempt), self.max_delay_s)
        if self.jitter <= 0.0:
            return d
        if rng is None:
            rng = random.Random(self.seed) if self.seed is not None \
                else random
        return d * (1.0 - self.jitter * rng.random())


#: default policy used by the batcher
DEFAULT_RETRY = RetryPolicy()
