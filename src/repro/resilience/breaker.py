"""Per-bundle circuit breakers with accurate-path fallback routing.

A :class:`CircuitBreaker` guards one surrogate bundle key and decides
whether traffic may use the surrogate (``allow()``) based on a dispatch
failure-rate EWMA *and* the PR-7 shadow-quality alert state:

::

    CLOSED ──(EWMA >= threshold, >= min_samples) or quality CRITICAL──► OPEN
    OPEN   ──cooldown elapsed──► HALF_OPEN (probe trickle)
    HALF_OPEN ──probe failure or quality still CRITICAL──► OPEN (re-stamped)
    HALF_OPEN ──probe_n consecutive probe successes──► CLOSED (EWMA reset)

While OPEN, ``MLRegion`` routes through its accurate function instead of
raising or serving junk — the predicated-region contract turned into a
safety valve.  HALF_OPEN admits every ``probe_every``-th request as a
probe so recovery is detected without re-exposing the full traffic.

Anti-flap hysteresis: closing from HALF_OPEN zeroes the EWMA *and* the
sample count, so a re-trip needs ``min_samples`` fresh failures — the
breaker cannot oscillate CLOSED↔OPEN on a single borderline observation
(property-tested in ``tests/test_resilience.py``).

The process-wide :data:`BREAKERS` board is enabled by default; set
``REPRO_BREAKER=0`` to disable (every ``allow`` returns True and
recording is a no-op).  This module imports only ``repro.obs`` — the
serve layer imports *us*, never the reverse.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Dict, Optional

import os

from repro.obs import metrics as _metrics
from repro.obs.quality import CRITICAL, SHADOW

ENV_BREAKER = "REPRO_BREAKER"

CLOSED, OPEN, HALF_OPEN = "CLOSED", "OPEN", "HALF_OPEN"
_STATE_NUM = {CLOSED: 0, OPEN: 1, HALF_OPEN: 2}

_STATE_G = _metrics.gauge(
    "repro_resilience_breaker_state",
    "circuit breaker state per bundle (0=CLOSED 1=OPEN 2=HALF_OPEN)",
    ("key",))
_TRANSITIONS = _metrics.counter(
    "repro_resilience_breaker_transitions_total",
    "breaker state transitions", ("key", "to"))
_FALLBACK = _metrics.counter(
    "repro_resilience_fallback_total",
    "requests routed to the accurate path by the breaker",
    ("key", "path"))


@dataclasses.dataclass(frozen=True)
class BreakerPolicy:
    """Tunables for one breaker."""

    failure_threshold: float = 0.5   # EWMA failure rate that trips CLOSED
    ewma_alpha: float = 0.3          # weight of the newest observation
    min_samples: int = 4             # observations before the EWMA counts
    open_cooldown_s: float = 1.0     # OPEN dwell before probing
    probe_n: int = 3                 # consecutive probe successes to close
    probe_every: int = 4             # HALF_OPEN admits every k-th request


class CircuitBreaker:
    """One bundle's CLOSED→OPEN→HALF_OPEN state machine.  Thread-safe;
    the clock is injectable so tests can drive transitions without
    sleeping."""

    def __init__(self, key: str, policy: Optional[BreakerPolicy] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.key = key
        self.policy = policy or BreakerPolicy()
        self._clock = clock
        self._lock = threading.Lock()
        self._state = CLOSED
        self._ewma = 0.0
        self._samples = 0
        self._opened_at = 0.0
        self._probe_ok = 0
        self._probe_seq = 0
        _STATE_G.set(0, key=key)

    # -- state plumbing ----------------------------------------------------
    def _set_state(self, to: str) -> None:
        if to == self._state:
            return
        self._state = to
        _STATE_G.set(_STATE_NUM[to], key=self.key)
        _TRANSITIONS.inc(1, key=self.key, to=to)
        if to == OPEN:
            self._opened_at = self._clock()
            self._probe_ok = 0
            self._probe_seq = 0
        elif to == CLOSED:
            # hysteresis: a re-trip needs min_samples fresh observations
            self._ewma = 0.0
            self._samples = 0

    def _quality_critical(self) -> bool:
        try:
            return SHADOW.state(self.key) == CRITICAL
        except Exception:
            return False

    # -- public API --------------------------------------------------------
    @property
    def state(self) -> str:
        with self._lock:
            return self._state

    def allow(self) -> bool:
        """May this request use the surrogate right now?  May transition
        CLOSED→OPEN (quality latch) or OPEN→HALF_OPEN (cooldown)."""
        with self._lock:
            if self._state == CLOSED:
                if self._quality_critical():
                    self._set_state(OPEN)
                    return False
                return True
            if self._state == OPEN:
                if (self._clock() - self._opened_at
                        >= self.policy.open_cooldown_s):
                    self._set_state(HALF_OPEN)
                    self._probe_seq = 1
                    return True  # first probe
                return False
            # HALF_OPEN: admit every probe_every-th request as a probe
            self._probe_seq += 1
            return (self._probe_seq - 1) % max(1, self.policy.probe_every) == 0

    def record_success(self) -> None:
        with self._lock:
            if self._state == HALF_OPEN:
                self._probe_ok += 1
                if (self._probe_ok >= self.policy.probe_n
                        and not self._quality_critical()):
                    self._set_state(CLOSED)
                return
            self._samples += 1
            a = self.policy.ewma_alpha
            self._ewma = (1.0 - a) * self._ewma

    def record_failure(self) -> None:
        with self._lock:
            if self._state == HALF_OPEN:
                self._set_state(OPEN)  # probe failed: re-open, re-stamp
                return
            self._samples += 1
            a = self.policy.ewma_alpha
            self._ewma = (1.0 - a) * self._ewma + a
            if (self._state == CLOSED
                    and self._samples >= self.policy.min_samples
                    and self._ewma >= self.policy.failure_threshold):
                self._set_state(OPEN)

    def snapshot(self) -> dict:
        with self._lock:
            return {"key": self.key, "state": self._state,
                    "ewma": round(self._ewma, 4),
                    "samples": self._samples,
                    "probe_ok": self._probe_ok}


class BreakerBoard:
    """Lazy per-key breakers.  Disabled (``REPRO_BREAKER=0``) every call
    is a no-op and ``allow`` is always True."""

    def __init__(self, enabled: Optional[bool] = None):
        if enabled is None:
            enabled = os.environ.get(ENV_BREAKER, "1") not in ("0", "false")
        self.enabled = enabled
        self._lock = threading.Lock()
        self._breakers: Dict[str, CircuitBreaker] = {}

    def get(self, key: str) -> CircuitBreaker:
        with self._lock:
            b = self._breakers.get(key)
            if b is None:
                b = self._breakers[key] = CircuitBreaker(key)
            return b

    def configure(self, key: str, policy: BreakerPolicy,
                  clock: Callable[[], float] = time.monotonic
                  ) -> CircuitBreaker:
        """Install a breaker with a custom policy (benches, tests)."""
        with self._lock:
            b = CircuitBreaker(key, policy, clock)
            self._breakers[key] = b
            return b

    def reset(self, key: Optional[str] = None) -> None:
        with self._lock:
            if key is None:
                self._breakers.clear()
            else:
                self._breakers.pop(key, None)

    def allow(self, key: str) -> bool:
        if not self.enabled:
            return True
        return self.get(key).allow()

    def record_success(self, key: str) -> None:
        if self.enabled:
            self.get(key).record_success()

    def record_failure(self, key: str) -> None:
        if self.enabled:
            self.get(key).record_failure()

    def note_fallback(self, key: str, path: str) -> None:
        _FALLBACK.inc(1, key=key, path=path)

    def snapshot(self) -> dict:
        with self._lock:
            return {k: b.snapshot() for k, b in self._breakers.items()}


#: process-wide breaker board (enabled unless REPRO_BREAKER=0)
BREAKERS = BreakerBoard()
