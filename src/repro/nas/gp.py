"""Gaussian process regression (numpy): Matérn-5/2 + Cholesky.

Small and dependency-free — the Ax/BoTorch role in the paper's workflow.
Inputs are normalized to [0, 1]^d by the caller (see space.py).
"""
from __future__ import annotations

import numpy as np


def matern52(X1, X2, lengthscale, variance):
    d = np.sqrt(np.maximum(
        ((X1[:, None, :] - X2[None, :, :]) / lengthscale) ** 2, 0).sum(-1))
    s5 = np.sqrt(5.0) * d
    return variance * (1 + s5 + s5 ** 2 / 3.0) * np.exp(-s5)


class GP:
    def __init__(self, lengthscale=0.3, variance=1.0, noise=1e-4):
        self.ls, self.var, self.noise = lengthscale, variance, noise
        self.X = None

    def fit(self, X, y):
        X = np.asarray(X, float)
        y = np.asarray(y, float).reshape(-1)
        self.ymu, self.ystd = y.mean(), max(y.std(), 1e-9)
        yn = (y - self.ymu) / self.ystd
        # light lengthscale selection by marginal likelihood over a grid
        best = (None, -np.inf)
        for ls in (0.1, 0.2, 0.3, 0.5, 1.0):
            K = matern52(X, X, ls, self.var) + self.noise * np.eye(len(X))
            try:
                L = np.linalg.cholesky(K)
            except np.linalg.LinAlgError:
                continue
            a = np.linalg.solve(L.T, np.linalg.solve(L, yn))
            ll = -0.5 * yn @ a - np.log(np.diag(L)).sum()
            if ll > best[1]:
                best = (ls, ll)
        self.ls = best[0] or self.ls
        K = matern52(X, X, self.ls, self.var) + self.noise * np.eye(len(X))
        self.L = np.linalg.cholesky(K)
        self.alpha = np.linalg.solve(self.L.T, np.linalg.solve(self.L, yn))
        self.X = X
        return self

    def predict(self, Xs):
        Ks = matern52(np.asarray(Xs, float), self.X, self.ls, self.var)
        mu = Ks @ self.alpha
        v = np.linalg.solve(self.L, Ks.T)
        var = np.maximum(self.var - (v ** 2).sum(0), 1e-12)
        return mu * self.ystd + self.ymu, np.sqrt(var) * self.ystd
