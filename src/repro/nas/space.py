"""Search-space parameterization: named dims -> unit cube <-> configs."""
from __future__ import annotations

import math

import numpy as np


class Dim:
    def __init__(self, name, lo, hi, kind="float"):
        self.name, self.lo, self.hi, self.kind = name, lo, hi, kind

    def decode(self, u):
        if self.kind == "float":
            return self.lo + u * (self.hi - self.lo)
        if self.kind == "int":
            return int(round(self.lo + u * (self.hi - self.lo)))
        if self.kind == "log2":
            lo = math.log2(max(self.lo, 1))
            hi = math.log2(self.hi)
            return int(2 ** round(lo + u * (hi - lo)))
        raise ValueError(self.kind)


class Space:
    def __init__(self, dims):
        self.dims = dims

    @property
    def d(self):
        return len(self.dims)

    def sample(self, rng, n):
        return rng.uniform(0, 1, (n, self.d))

    def decode(self, u):
        return {dim.name: dim.decode(float(x))
                for dim, x in zip(self.dims, u)}


# Paper Table V: the (inner) training hyper-parameter space
def hyper_space():
    return Space([
        Dim("lr", 1e-4, 1e-2, "float"),
        Dim("weight_decay", 1e-4, 1e-1, "float"),
        Dim("dropout", 0.0, 0.8, "float"),
        Dim("batch_size", 32, 512, "log2"),
    ])


def arch_space(app_space: dict) -> Space:
    """Paper Table IV, per benchmark kind."""
    dims = []
    if app_space["kind"] == "mlp":
        if "n_hidden" in app_space:
            dims.append(Dim("n_hidden", *app_space["n_hidden"], "int"))
            dims.append(Dim("hidden1", app_space["hidden1"][0],
                            app_space["hidden1"][1], "log2"))
            dims.append(Dim("feature_mult", *app_space["feature_mult"],
                            "float"))
        else:
            dims.append(Dim("hidden1", app_space["hidden1"][0],
                            app_space["hidden1"][1], "log2"))
            dims.append(Dim("hidden2", 1, app_space["hidden2"][1], "log2"))
    else:  # cnn
        for key, rng in app_space.items():
            if key in ("kind", "grid", "in_ch", "out_ch"):
                continue
            lo, hi = rng
            dims.append(Dim(key, lo, hi, "int"))
    return Space(dims)


def build_net(app_space: dict, arch_cfg: dict, dropout=0.0):
    """Instantiate the Sequential for one sampled architecture."""
    from repro.nn.layers import CNN, MLP
    if app_space["kind"] == "mlp":
        if "n_hidden" in app_space:
            widths = []
            w = arch_cfg["hidden1"]
            for _ in range(arch_cfg["n_hidden"]):
                widths.append(max(4, int(w)))
                w = w * arch_cfg["feature_mult"]
            hidden = widths
        else:
            hidden = [arch_cfg["hidden1"]]
            if arch_cfg.get("hidden2", 0) > 1:
                hidden.append(arch_cfg["hidden2"])
        return MLP((1, app_space["in_dim"]), hidden, app_space["out_dim"],
                   dropout=dropout)
    gh, gw = app_space["grid"]
    convs = []
    if "conv_k" in arch_cfg:  # particlefilter-style
        k = max(2, arch_cfg["conv_k"])
        s = max(1, arch_cfg.get("stride", 1))
        convs.append((8, k, s))
    else:  # miniweather-style
        convs.append((arch_cfg.get("ch1", 8), max(2, arch_cfg.get("k1", 3)), 1))
        if arch_cfg.get("k2", 0) >= 2:
            convs.append((app_space["out_ch"] * 4, arch_cfg["k2"], 1))
    dense = []
    if arch_cfg.get("fc2", 0) > 8:
        dense.append(arch_cfg["fc2"])
    out_dim = app_space["out_ch"]
    if app_space.get("dense_out", True) and app_space["out_ch"] <= 4 and \
            "conv_k" in arch_cfg:
        # regression head (particlefilter): flatten -> fc -> (x, y)
        from repro.nn.layers import CNN as _CNN
        pool = max(1, arch_cfg.get("pool", 1))
        return _CNN((1, gh, gw, app_space["in_ch"]), convs, dense, out_dim,
                    pool=pool if pool > 1 else None)
    # dense prediction (miniweather): conv stack, same-size output
    from repro.nn.layers import Activation, Conv2D, Sequential
    layers = []
    cin = app_space["in_ch"]
    for f, k, s in convs:
        layers += [Conv2D(f, k, 1, "SAME"), Activation("relu")]
    layers.append(Conv2D(app_space["out_ch"], 3, 1, "SAME"))
    return Sequential(layers, (1, gh, gw, app_space["in_ch"]))
