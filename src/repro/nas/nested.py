"""Nested two-level Bayesian optimization (paper §V-C).

Outer level: multi-objective (inference latency, validation error) over
the architecture space — ParEGO-style random Chebyshev scalarization with
a GP + expected improvement, early-stopped after ``stall`` non-improving
trials (paper: 5).  Architectures on the Pareto front are then tuned in
the inner level over the Table-V hyper-parameter space.
"""
from __future__ import annotations

import math

import numpy as np

from repro.nas.gp import GP
from repro.nas.space import Space, arch_space, build_net, hyper_space
from repro.nas.train_surrogate import fit, latency


def expected_improvement(mu, sd, best):
    z = (best - mu) / np.maximum(sd, 1e-9)
    Phi = 0.5 * (1 + np.vectorize(math.erf)(z / math.sqrt(2)))
    phi = np.exp(-0.5 * z ** 2) / math.sqrt(2 * math.pi)
    return (best - mu) * Phi + sd * phi


def bo_minimize(objective, space: Space, *, iters=20, init=5, seed=0,
                stall=5):
    """Single-objective BO. Returns (best_cfg, best_val, history)."""
    rng = np.random.default_rng(seed)
    U = space.sample(rng, init)
    ys, hist = [], []
    for u in U:
        cfg = space.decode(u)
        y = objective(cfg)
        ys.append(y)
        hist.append((cfg, y))
    U = list(U)
    bad = 0
    for it in range(iters - init):
        gp = GP().fit(np.asarray(U), np.asarray(ys))
        cand = space.sample(rng, 256)
        mu, sd = gp.predict(cand)
        ei = expected_improvement(mu, sd, min(ys))
        u = cand[int(np.argmax(ei))]
        cfg = space.decode(u)
        y = objective(cfg)
        improved = y < min(ys) - 1e-12
        U.append(u)
        ys.append(y)
        hist.append((cfg, y))
        bad = 0 if improved else bad + 1
        if bad >= stall:
            break
    i = int(np.argmin(ys))
    return hist[i][0], ys[i], hist


def pareto_front(points):
    """Indices of non-dominated (minimize both) points."""
    pts = np.asarray(points, float)
    keep = []
    for i, p in enumerate(pts):
        dominated = ((pts <= p).all(1) & (pts < p).any(1)).any()
        if not dominated:
            keep.append(i)
    return keep


def nested_search(app, db_group, *, outer_iters=12, inner_iters=6, seed=0,
                  epochs=25, stall=5, verbose=True):
    """Paper §V-C: outer NAS (latency+error Pareto) -> inner HPO.

    Returns dict with trials (arch cfg, latency, val_rmse, params, net) and
    the Pareto-front indices.
    """
    space_cfg = app.surrogate_space()
    aspace = arch_space(space_cfg)
    data = db_group.load()
    X = data["inputs"].reshape(data["inputs"].shape[0], -1)
    Y = data["outputs"].reshape(data["outputs"].shape[0], -1)
    x_reshape = None
    if space_cfg["kind"] == "cnn":
        gh, gw = space_cfg["grid"]
        x_reshape = (gh, gw, space_cfg["in_ch"])

    rng = np.random.default_rng(seed)
    trials = []

    def eval_arch(cfg):
        net = build_net(space_cfg, cfg)
        params, val_rmse, stats = fit(net, X, Y, epochs=epochs,
                                      seed=seed, x_reshape=x_reshape)
        in_shape = (256,) + tuple(net.in_shape[1:])
        lat = latency(net, params, in_shape)
        trials.append({"arch": cfg, "latency": lat, "val_rmse": val_rmse,
                       "net": net, "params": params, "stats": stats})
        if verbose:
            print(f"  [outer] {cfg} -> rmse={val_rmse:.4g} lat={lat*1e3:.2f}ms",
                  flush=True)
        return val_rmse, lat

    # ---- outer: ParEGO scalarization ----
    U = aspace.sample(rng, min(4, outer_iters))
    for u in U:
        eval_arch(aspace.decode(u))
    U = list(U)
    bad = 0
    while len(trials) < outer_iters and bad < stall:
        errs = np.asarray([t["val_rmse"] for t in trials])
        lats = np.asarray([t["latency"] for t in trials])
        ne = (errs - errs.min()) / max(np.ptp(errs), 1e-12)
        nl = (lats - lats.min()) / max(np.ptp(lats), 1e-12)
        w = rng.uniform(0.1, 0.9)
        scal = np.maximum(w * ne, (1 - w) * nl) + 0.05 * (w * ne + (1 - w) * nl)
        gp = GP().fit(np.asarray(U), scal)
        cand = aspace.sample(rng, 256)
        mu, sd = gp.predict(cand)
        ei = expected_improvement(mu, sd, scal.min())
        u = cand[int(np.argmax(ei))]
        n_before = len(pareto_front(np.stack([errs, lats], 1)))
        eval_arch(aspace.decode(u))
        U.append(u)
        errs2 = np.asarray([t["val_rmse"] for t in trials])
        lats2 = np.asarray([t["latency"] for t in trials])
        improved = len(pareto_front(np.stack([errs2, lats2], 1))) > n_before \
            or errs2[-1] <= errs.min() or lats2[-1] <= lats.min()
        bad = 0 if improved else bad + 1

    # ---- inner: hyper-parameter tuning of Pareto archs ----
    errs = np.asarray([t["val_rmse"] for t in trials])
    lats = np.asarray([t["latency"] for t in trials])
    front = pareto_front(np.stack([errs, lats], 1))
    hspace = hyper_space()
    for fi in front:
        t = trials[fi]

        def obj(h):
            net = build_net(space_cfg, t["arch"], dropout=h["dropout"])
            params, rmse, stats = fit(
                net, X, Y, lr=h["lr"], weight_decay=h["weight_decay"],
                batch_size=h["batch_size"], epochs=epochs, seed=seed,
                x_reshape=x_reshape)
            if rmse < t["val_rmse"]:
                t.update(params=params, val_rmse=rmse, stats=stats, net=net,
                         hypers=h)
            return rmse

        if inner_iters > 0:
            bo_minimize(obj, hspace, iters=inner_iters,
                        init=min(3, inner_iters), seed=seed + fi, stall=3)
    errs = np.asarray([t["val_rmse"] for t in trials])
    lats = np.asarray([t["latency"] for t in trials])
    return {"trials": trials,
            "pareto": pareto_front(np.stack([errs, lats], 1))}


def save_trial(trial, path):
    """Persist a searched surrogate as a loadable model bundle.

    Invalidates any engine already serving this path, so regions pick up
    the retrained weights instead of the process-wide cached ones.
    """
    from repro.core.engine import InferenceEngine
    from repro.nn.serialize import save_model
    out = save_model(path, trial["net"], trial["params"],
                     extra=trial["stats"])
    InferenceEngine.invalidate(out)
    return out


def best_trial(result, weight_error=1.0):
    """Lowest-validation-error Pareto member (paper's deployment pick)."""
    front = result["pareto"]
    return min((result["trials"][i] for i in front),
               key=lambda t: t["val_rmse"])
