"""Surrogate training on SurrogateDB data: Adam + early stopping.

Normalization stats ride along in the model bundle's ``extra`` field so
the inference engine reproduces them at deployment (the paper stores the
equivalent inside the TorchScript module).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _adam(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8, wd=0.0):
    m, v, t = state
    t = t + 1
    m = jax.tree.map(lambda a, g: b1 * a + (1 - b1) * g, m, grads)
    v = jax.tree.map(lambda a, g: b2 * a + (1 - b2) * g * g, v, grads)
    c1, c2 = 1 - b1 ** t, 1 - b2 ** t

    def upd(p, mm, vv):
        return p - lr * ((mm / c1) / (jnp.sqrt(vv / c2) + eps) + wd * p)

    return jax.tree.map(upd, params, m, v), (m, v, t)


def fit(net, X, Y, *, lr=1e-3, weight_decay=0.0, dropout=0.0, batch_size=128,
        epochs=60, val_frac=0.2, seed=0, patience=8, x_reshape=None):
    """Train `net` on numpy (X, Y). Returns (params, val_rmse, norm_stats)."""
    rng = np.random.default_rng(seed)
    n = X.shape[0]
    perm = rng.permutation(n)
    cut = max(1, int(n * (1 - val_frac)))
    tr, va = perm[:cut], perm[cut:]
    x_mu, x_sd = X[tr].mean(0), X[tr].std(0) + 1e-6
    y_mu, y_sd = Y[tr].mean(0), Y[tr].std(0) + 1e-6
    Xn = (X - x_mu) / x_sd
    Yn = (Y - y_mu) / y_sd
    if x_reshape is not None:
        Xn = Xn.reshape((-1,) + tuple(x_reshape))
    Xtr, Ytr = jnp.asarray(Xn[tr]), jnp.asarray(Yn[tr])
    Xva, Yva = jnp.asarray(Xn[va]), jnp.asarray(Yn[va])

    params = net.init(jax.random.PRNGKey(seed))
    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)
    opt = (m, v, 0)

    def loss_fn(p, xb, yb, key):
        pred = net.apply(p, xb, train=True, rng=key)
        return ((pred - yb.reshape(pred.shape)) ** 2).mean()

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    val_fn = jax.jit(lambda p: ((net.apply(p, Xva)
                                 - Yva.reshape(-1, *net.out_shape()[1:]))
                                ** 2).mean())

    best, best_params, bad = np.inf, params, 0
    key = jax.random.PRNGKey(seed + 1)
    bs = min(batch_size, len(tr))
    for ep in range(epochs):
        order = rng.permutation(len(tr))
        for i in range(0, len(order) - bs + 1, bs):
            idx = order[i:i + bs]
            key, k = jax.random.split(key)
            _, g = grad_fn(params, Xtr[idx], Ytr[idx], k)
            params, opt = _adam(params, g, opt, lr, wd=weight_decay)
        vl = float(val_fn(params))
        if vl < best - 1e-6:
            best, best_params, bad = vl, params, 0
        else:
            bad += 1
            if bad >= patience:
                break
    # de-normalized validation RMSE
    val_rmse = float(np.sqrt(best) * np.mean(y_sd))
    stats = {"x_mu": x_mu.tolist(), "x_sd": x_sd.tolist(),
             "y_mu": y_mu.tolist(), "y_sd": y_sd.tolist()}
    return best_params, val_rmse, stats


def latency(net, params, in_shape, reps=10):
    """Median jit'd inference wall time (the paper's latency objective)."""
    x = jnp.zeros(in_shape, jnp.float32)
    f = jax.jit(lambda p, x: net.apply(p, x))
    f(params, x).block_until_ready()
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        f(params, x).block_until_ready()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))
